"""WaveServe (runtime.wave_serve, DESIGN.md §WaveServe): the workload-
adapter contracts — per-adapter padding bit-invariance, wave outputs
matching the direct ``generate``/``moe_forward``/classifier calls, the
'moe' Router algorithm through ``build_router``, chaos modes (transient
error, NaN guard, crash) through the LM adapter with zero lost requests,
the ``_LM_FNS`` lock regression, the classifier deprecation shim, and a
mixed CapsNet + LM + MoE fleet whose per-workload books balance.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.configs.caps_benchmarks import CapsConfig
from repro.core.router import RouterSpec, build_router, get_algorithm
from repro.models import capsnet, lm
from repro.models import moe as moe_lib
from repro.runtime import serve_loop, wave_serve
from repro.runtime.caps_fleet import CapsFleet, TenantPolicy
from repro.runtime.caps_serve import CapsAdapter
from repro.runtime.elastic import ElasticPolicy
from repro.runtime.faults import FaultEvent, FaultPlan, chaos_wave_fn, \
    fleet_wrap
from repro.runtime.serve_loop import LMDecodeAdapter, MoEAdapter
from repro.runtime.wave_serve import ServeConfig, WaveServer

PROMPT_LEN = 6
MAX_NEW = 3
SEQ_LEN = 4


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_smoke_config("granite-3-2b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def moe_setup():
    # capacity_factor >= n_experts/top_k: capacity == token count, so no
    # token is ever dropped and padding bit-invariance is exact
    cfg = moe_lib.MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                            capacity_factor=2.0)
    params = moe_lib.init_moe(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    return cfg, params


@pytest.fixture(scope="module")
def caps_setup():
    cfg = CapsConfig("Caps-tiny", "synthetic", 8, 72, 10, 2,
                     caps_channels=2, conv_channels=16)
    params = capsnet.init_capsnet(jax.random.PRNGKey(2), cfg)
    # non-zero conv biases so pad lanes produce non-zero votes: padding
    # invariance genuinely depends on the adapter's lane mask
    params["primary"]["conv1"]["b"] = params["primary"]["conv1"]["b"] + 0.1
    return cfg, params


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, (n, PROMPT_LEN), dtype=np.int32)


def _blocks(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, SEQ_LEN, cfg.d_model)).astype(np.float32)


# ---------------------------------------------------------------------------
# Adapter contracts: wave == direct call, padding bit-invariance
# ---------------------------------------------------------------------------

def test_lm_adapter_wave_matches_generate(lm_setup):
    cfg, params = lm_setup
    adapter = LMDecodeAdapter(params, cfg, prompt_len=PROMPT_LEN,
                              max_new_tokens=MAX_NEW)
    scfg = ServeConfig(microbatch=2, n_micro=2, pipeline=None)
    prompts = _prompts(cfg, scfg.wave_lanes)
    wave = adapter.make_wave_fn(scfg)
    out = wave(adapter.pack(list(prompts), scfg))
    direct, _ = serve_loop.generate(params, cfg,
                                    {"tokens": jnp.asarray(prompts)},
                                    MAX_NEW)
    results = adapter.unpack(out, len(prompts))
    assert all(r.dtype == np.int32 for r in results)
    np.testing.assert_array_equal(np.stack(results), np.asarray(direct))


def test_lm_adapter_padding_bit_invariant(lm_setup):
    cfg, params = lm_setup
    adapter = LMDecodeAdapter(params, cfg, prompt_len=PROMPT_LEN,
                              max_new_tokens=MAX_NEW)
    scfg = ServeConfig(microbatch=2, n_micro=2, pipeline=None)
    wave = adapter.make_wave_fn(scfg)
    prompts = _prompts(cfg, 3)                 # 3 real lanes, 1 padded
    padded = adapter.unpack(wave(adapter.pack(list(prompts), scfg)), 3)
    full = _prompts(cfg, scfg.wave_lanes)
    full[:3] = prompts
    unpadded = adapter.unpack(wave(adapter.pack(list(full), scfg)), 3)
    for a, b in zip(padded, unpadded):
        np.testing.assert_array_equal(a, b)


def test_moe_adapter_wave_matches_moe_forward(moe_setup):
    cfg, params = moe_setup
    adapter = MoEAdapter(params, cfg, seq_len=SEQ_LEN)
    scfg = ServeConfig(microbatch=2, n_micro=2, pipeline=None)
    blocks = _blocks(cfg, scfg.wave_lanes)
    wave = adapter.make_wave_fn(scfg)
    results = adapter.unpack(wave(adapter.pack(list(blocks), scfg)),
                             len(blocks))
    direct, _aux = moe_lib.moe_forward(params, jnp.asarray(blocks), cfg)
    np.testing.assert_allclose(np.stack(results), np.asarray(direct),
                               atol=1e-5)


def test_moe_adapter_padding_bit_invariant(moe_setup):
    cfg, params = moe_setup
    adapter = MoEAdapter(params, cfg, seq_len=SEQ_LEN)
    scfg = ServeConfig(microbatch=2, n_micro=2, pipeline=None)
    wave = adapter.make_wave_fn(scfg)
    blocks = _blocks(cfg, 3)
    padded = adapter.unpack(wave(adapter.pack(list(blocks), scfg)), 3)
    full = _blocks(cfg, scfg.wave_lanes, seed=9)
    full[:3] = blocks
    unpadded = adapter.unpack(wave(adapter.pack(list(full), scfg)), 3)
    np.testing.assert_array_equal(np.stack(padded), np.stack(unpadded))


def test_caps_adapter_padding_bit_invariant(caps_setup):
    # caps routing couples batch lanes through the shared b logits, so the
    # invariance is mask-mediated: a padded lane's *content* must be
    # bit-irrelevant, and the padded wave must match an unpadded reference
    cfg, params = caps_setup
    adapter = CapsAdapter(params, cfg)
    scfg = ServeConfig(microbatch=4, n_micro=1, pipeline="software")
    wave = adapter.make_wave_fn(scfg)
    rng = np.random.default_rng(0)
    shape = (cfg.image_hw, cfg.image_hw, cfg.image_channels)
    images = rng.random((3,) + shape, np.float32)
    micro = adapter.pack(list(images), scfg)
    padded = np.asarray(wave(micro))
    # garbage in the masked pad lane: bit-identical output required
    garbage = np.asarray(micro["images"]).copy()
    garbage.reshape(scfg.wave_lanes, *shape)[3] = rng.random(shape)
    poked = np.asarray(wave({"images": jnp.asarray(garbage),
                             "mask": micro["mask"]}))
    np.testing.assert_array_equal(padded, poked)
    # and the real lanes are bit-equal to a no-padding wave of just the
    # real images: the masked pad lane contributes exactly zero to every
    # cross-lane routing reduction
    ref_cfg = ServeConfig(microbatch=3, n_micro=1, pipeline="software")
    ref = np.asarray(adapter.make_wave_fn(ref_cfg)(
        adapter.pack(list(images), ref_cfg)))
    np.testing.assert_array_equal(padded.reshape(-1, padded.shape[-1])[:3],
                                  ref.reshape(-1, ref.shape[-1]))


def test_moe_algorithm_registered_through_build_router(moe_setup):
    cfg, params = moe_setup
    algo = get_algorithm("moe")
    assert algo.sharded_dims == ("E",) and algo.num_inputs == 5
    spec = RouterSpec(algorithm="moe", options=(("moe_cfg", cfg),))
    router = build_router(spec)
    x = _blocks(cfg, 2)
    x2d = jnp.asarray(x.reshape(2 * SEQ_LEN, cfg.d_model))
    y, aux = router(x2d, *moe_lib.router_args(params))
    direct, direct_aux = moe_lib.moe_forward(params, jnp.asarray(x), cfg)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(direct).reshape(2 * SEQ_LEN, cfg.d_model),
        atol=1e-6)
    assert float(aux) == pytest.approx(float(direct_aux))
    # the static MoEConfig is mandatory
    with pytest.raises(ValueError, match="moe_cfg"):
        build_router(RouterSpec(algorithm="moe"))(
            x2d, *moe_lib.router_args(params))


# ---------------------------------------------------------------------------
# Generic server + chaos through the LM adapter (zero lost requests)
# ---------------------------------------------------------------------------

def _drive(server, items_fn, total, chunk=3):
    submitted = 0
    while submitted < total:
        n = min(chunk, total - submitted)
        server.submit(items_fn(n, submitted))
        submitted += n
    return server.drain()


def test_lm_adapter_serves_through_wave_server(lm_setup):
    cfg, params = lm_setup
    adapter = LMDecodeAdapter(params, cfg, prompt_len=PROMPT_LEN,
                              max_new_tokens=MAX_NEW)
    server = WaveServer(adapter,
                        cfg=ServeConfig(microbatch=2, n_micro=2,
                                        pipeline=None))
    done = _drive(server, lambda n, s: _prompts(cfg, n, seed=s), 10)
    m = server.metrics
    assert m.submitted == m.completed == len(done) == 10
    assert server.pending() == 0
    direct, _ = serve_loop.generate(
        params, cfg, {"tokens": jnp.asarray(_prompts(cfg, 3, seed=0))},
        MAX_NEW)
    by_rid = {c.rid: c.pred for c in done}
    np.testing.assert_array_equal(np.stack([by_rid[r] for r in (0, 1, 2)]),
                                  np.asarray(direct))


def test_lm_chaos_error_and_corrupt_zero_loss(lm_setup):
    cfg, params = lm_setup
    adapter = LMDecodeAdapter(params, cfg, prompt_len=PROMPT_LEN,
                              max_new_tokens=MAX_NEW)
    scfg = ServeConfig(microbatch=2, n_micro=2, pipeline=None)
    wrapped = chaos_wave_fn(adapter.make_wave_fn(scfg),
                            FaultPlan((FaultEvent(0, "error"),
                                       FaultEvent(2, "corrupt"))))
    server = WaveServer(adapter, cfg=scfg, wave_fn=wrapped)
    done = _drive(server, lambda n, s: _prompts(cfg, n, seed=s), 8)
    m = server.metrics
    # transient error retried, NaN wave quarantined through the reference
    # re-run — every request completes, none lost or failed
    assert m.completed == len(done) == 8 and m.failed == 0
    assert m.wave_errors >= 1 and m.retried >= 1 and m.requeued >= 1
    assert m.guard_trips >= 1
    assert m.submitted == m.completed + m.shed + m.failed
    assert server.pending() == 0
    # quarantined completions still carry real predictions
    direct, _ = serve_loop.generate(
        params, cfg, {"tokens": jnp.asarray(_prompts(cfg, 3, seed=0))},
        MAX_NEW)
    by_rid = {c.rid: c.pred for c in done}
    np.testing.assert_array_equal(np.stack([by_rid[r] for r in (0, 1, 2)]),
                                  np.asarray(direct))


def test_lm_chaos_crash_heals_through_fleet(lm_setup):
    cfg, params = lm_setup
    adapter = LMDecodeAdapter(params, cfg, prompt_len=PROMPT_LEN,
                              max_new_tokens=MAX_NEW)
    scfg = ServeConfig(microbatch=2, n_micro=1, pipeline=None,
                       queue_order="deadline")
    fleet = CapsFleet(params, None, models={"lm": (adapter, scfg)},
                      tenants=(TenantPolicy("t0", slo_s=60.0),),
                      policy=ElasticPolicy(min_replicas=2, max_replicas=2),
                      wave_wrap=fleet_wrap(
                          {"lm/r0": FaultPlan((FaultEvent(0, "crash"),))}))
    for s in range(4):
        fleet.submit(_prompts(cfg, 3, seed=s), tenant="t0", model="lm")
    fleet.drain()
    fleet.health_check()
    fleet.drain()
    s = fleet.summary()
    assert s["pending"] == 0 and s["failed"] == 0
    assert s["submitted"] == s["completed"] + s["shed"]
    assert s["completed"] == 12            # zero lost requests
    assert s["evacuated"] == s["adopted"] and s["evacuated"] > 0
    assert len(s["health_events"]) == 1    # the crash was buried once


# ---------------------------------------------------------------------------
# Mixed fleet: CapsNet + LM + MoE groups behind one front-end
# ---------------------------------------------------------------------------

def test_mixed_fleet_serves_all_three_workloads(caps_setup, lm_setup,
                                                moe_setup):
    caps_cfg, caps_params = caps_setup
    arch, lm_params = lm_setup
    moe_cfg, moe_params = moe_setup
    scfg = ServeConfig(microbatch=2, n_micro=2, pipeline=None,
                       queue_order="deadline")
    caps_scfg = ServeConfig(microbatch=2, n_micro=2, pipeline="software",
                            queue_order="deadline")
    fleet = CapsFleet(
        caps_params, caps_cfg,
        models={
            "caps": (None, caps_scfg),
            "lm": (LMDecodeAdapter(lm_params, arch, prompt_len=PROMPT_LEN,
                                   max_new_tokens=MAX_NEW), scfg),
            "moe": (MoEAdapter(moe_params, moe_cfg, seq_len=SEQ_LEN), scfg),
        },
        tenants=(TenantPolicy("caps", slo_s=60.0),
                 TenantPolicy("lm", slo_s=60.0),
                 TenantPolicy("moe", slo_s=60.0)),
        policy=ElasticPolicy(min_replicas=1, max_replicas=1))
    rng = np.random.default_rng(0)
    shape = (caps_cfg.image_hw, caps_cfg.image_hw, caps_cfg.image_channels)
    for s in range(3):
        fleet.submit(rng.random((3,) + shape, np.float32),
                     tenant="caps", model="caps")
        fleet.submit(_prompts(arch, 3, seed=s), tenant="lm", model="lm")
        fleet.submit(_blocks(moe_cfg, 3, seed=s), tenant="moe", model="moe")
    fleet.drain()
    s = fleet.summary()
    assert s["pending"] == 0 and s["failed"] == 0 and s["shed"] == 0
    assert s["completed"] == 27
    for name, t in s["per_tenant"].items():
        assert t["completed"] == t["submitted"] == 9, (name, t)
        assert t["goodput"] == 9, (name, t)
    # each group validates its own payload type — a caps image arrival
    # cannot enter the LM group
    with pytest.raises(ValueError):
        fleet.submit(rng.random((2,) + shape, np.float32),
                     tenant="lm", model="lm")


# ---------------------------------------------------------------------------
# Satellite regressions: _LM_FNS lock, classifier shim parity
# ---------------------------------------------------------------------------

def test_lm_fns_cache_concurrent_access(lm_setup):
    cfg, _params = lm_setup
    # distinct keys well past the LRU bound, hammered from many threads:
    # without the lock the get/insert/evict/move_to_end races corrupt the
    # OrderedDict (KeyError out of popitem / lost entries)
    errors = []

    def worker(w):
        try:
            for i in range(40):
                serve_loop._lm_fns(cfg, 32 + (w * 40 + i) % 24,
                                   serve_loop.NO_RULES)
        except Exception as e:    # noqa: BLE001 — the regression signal
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(serve_loop._LM_FNS) <= serve_loop._LM_FNS_MAX


def test_classifier_shim_parity(caps_setup):
    cfg, params = caps_setup
    classify, stats = serve_loop.make_capsnet_classifier(params, cfg,
                                                         max_batch=4)
    rng = np.random.default_rng(3)
    images = rng.random((5, cfg.image_hw, cfg.image_hw,
                         cfg.image_channels), np.float32)
    preds = classify(images)
    assert preds.shape == (5,) and preds.dtype == jnp.int32
    assert stats.requests == 5 and stats.batches == 2
    assert stats.padded_waste == 3
    # parity with the direct forward at the chunk grouping the shim uses:
    # class_probs is exactly the dynamic wave score, and the mask-invariant
    # padding means the ragged tail chunk matches an unpadded forward of
    # just its real images (the legacy inline path's unmasked zero-image
    # padding could not promise that)
    direct = jnp.concatenate([
        jnp.argmax(capsnet.forward(params, jnp.asarray(images[:4]),
                                   cfg)["class_probs"], -1),
        jnp.argmax(capsnet.forward(params, jnp.asarray(images[4:]),
                                   cfg)["class_probs"], -1)])
    np.testing.assert_array_equal(np.asarray(preds), np.asarray(direct))
    assert classify(images[:0]).shape == (0,)
