"""Paper §5.1.2 E/M cost models, execution-score planner, Fig.18 behaviour,
§5.3.2 RMAS optimum, and the beyond-paper MoE planner."""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # vendored fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import distribution as D
from repro.configs.caps_benchmarks import CAPS_BENCHMARKS


def shapes():
    return [D.RPShape.from_caps_config(c) for c in CAPS_BENCHMARKS.values()]


def test_E_closed_forms_positive_and_scale():
    dev = D.DeviceModel.hmc()
    for s in shapes():
        for dim in D.DIMS:
            e = D.workload_E(dim, s, dev.n_vault)
            assert e > 0
    # doubling L doubles E on every dimension (all forms are linear in N_L)
    s = shapes()[0]
    s2 = D.RPShape(s.n_b, 2 * s.n_l, s.n_h, s.c_l, s.c_h, s.iters)
    for dim in ("B", "H"):
        assert D.workload_E(dim, s2, 32) == pytest.approx(
            2 * D.workload_E(dim, s, 32), rel=1e-6)


def test_E_B_dimension_eq7():
    """Eq.7: E_B = ceil(N_B/nv) * N_L * N_H * ((4I-1)C_H + 2C_L·C_H - I)."""
    s = D.RPShape(n_b=100, n_l=1152, n_h=10, c_l=8, c_h=16, iters=3)
    want = math.ceil(100 / 32) * 1152 * 10 * ((4 * 3 - 1) * 16
                                              + 2 * 8 * 16 - 3)
    assert D.workload_E("B", s, 32) == pytest.approx(want)


def test_M_H_smallest_for_caps_mnist():
    """For Caps-MN1 geometry the H-dim moves the least data (Eq.12 has no
    N_B or N_H factor in its first term)."""
    s = D.RPShape(n_b=100, n_l=1152, n_h=10, c_l=8, c_h=16, iters=3)
    ms = {d: D.comm_M(d, s, 32) for d in D.DIMS}
    assert ms["H"] < ms["B"] and ms["H"] < ms["L"]


def test_plan_picks_argmax_score():
    dev = D.DeviceModel.hmc()
    for s in shapes():
        table = D.score_table(s, dev)
        assert D.plan(s, dev) == max(table, key=table.__getitem__)


def test_plan_shifts_with_device_coefficients():
    """Fig.18: the chosen dimension depends on the compute/comm balance.
    A compute-rich device weights M higher (pick min-comm dim); a
    bandwidth-rich device weights E higher (pick min-work dim)."""
    s = D.RPShape(n_b=100, n_l=576, n_h=10, c_l=8, c_h=16, iters=9)
    fast_compute = D.DeviceModel(alpha=1e-15, beta=1e-9, n_vault=32)
    fast_comm = D.DeviceModel(alpha=1e-9, beta=1e-15, n_vault=32)
    pick_fc = D.plan(s, fast_compute)
    pick_fm = D.plan(s, fast_comm)
    ms = {d: D.comm_M(d, s, 32) for d in D.DIMS}
    es = {d: D.workload_E(d, s, 32) for d in D.DIMS}
    assert pick_fc == min(ms, key=ms.__getitem__)
    assert pick_fm == min(es, key=es.__getitem__)


@settings(max_examples=50, deadline=None)
@given(nb=st.integers(1, 512), nl=st.integers(32, 8192),
       nh=st.integers(2, 128), i=st.integers(1, 9))
def test_property_scores_finite_positive(nb, nl, nh, i):
    s = D.RPShape(n_b=nb, n_l=nl, n_h=nh, c_l=8, c_h=16, iters=i)
    dev = D.DeviceModel.tpu_v5e(n_vault=16)
    for d in D.DIMS:
        sc = D.execution_score(d, s, dev)
        assert sc > 0 and math.isfinite(sc)


def test_rmas_optimum_near_argmin():
    """The paper's closed form floors the continuous optimum (Eq.15), so it
    may land one below the integer argmin — assert it's within one step and
    within 5% of the true minimum."""
    n_max, q, gv, gh = 12, 3.5, 1.0, 2.0
    star = D.rmas_optimal_grant(n_max, q, gv, gh)
    best = min(range(1, n_max + 1),
               key=lambda n: D.rmas_overhead(n, n_max, q, gv, gh))
    assert abs(star - best) <= 1
    assert D.rmas_overhead(star, n_max, q, gv, gh) <= \
        1.05 * D.rmas_overhead(best, n_max, q, gv, gh)


def test_rmas_bounds():
    assert D.rmas_optimal_grant(8, 1e9, 1.0, 1.0) == 0 or \
        D.rmas_optimal_grant(8, 1e9, 1.0, 1.0) >= 0
    assert D.rmas_optimal_grant(8, 1e-9, 1.0, 1.0) == 8


def test_moe_planner_prefers_expert_sharding_at_production_shape():
    """qwen3-30B geometry on the 16-way model axis: expert-sharded dispatch
    (psum combine) should beat all-to-all at modest top-k token volume."""
    s = D.MoEShape(tokens=4096, d_model=2048, d_ff=768, n_experts=128,
                   top_k=8)
    dev = D.DeviceModel.tpu_v5e(n_vault=16)
    t = D.moe_plan(s, dev)
    assert set(t) == {"expert", "token", "a2a"}
    assert all(v > 0 for v in t.values())


def test_estimated_time_consistent():
    s = shapes()[0]
    dev = D.DeviceModel.hmc()
    for d in D.DIMS:
        assert D.estimated_time_s(d, s, dev) == pytest.approx(
            1.0 / D.execution_score(d, s, dev))
